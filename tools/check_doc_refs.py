#!/usr/bin/env python3
"""Docs-consistency check: every ``path[:line]`` / ``path::symbol`` code
reference in the given markdown files must resolve against the repo.

Guards ``docs/ARCHITECTURE.md`` (the normative plane <-> kernel contract) and
the READMEs against silent rot: a reference to a file that was moved, a line
that no longer exists, or a test that was renamed fails CI.

Rules, applied to every backtick-quoted token that looks like a file path:

* the path must exist — resolved against the repo root, then against the
  markdown file's own directory (so ``benchmarks/README.md`` can list its
  sibling modules by bare name);
* ``path:N`` — the file must have at least N lines;
* ``path::name`` (pytest-style) — ``name`` must occur in the file's text.

Additionally, the "Kernel memory plans" pinned-footprint table in
``docs/ARCHITECTURE.md`` must name exactly the kernels budgeted in
``src/repro/kernels/budgets.py``, and the "Static contracts" rule table
must agree — id *and* name, both directions — with the planelint rules
registered in ``src/repro/analysis/lint/rules/`` (both sides are
AST-parsed — this script runs without ``PYTHONPATH=src`` in CI).

Usage:  python tools/check_doc_refs.py [file.md ...]
        (default: docs/ARCHITECTURE.md README.md benchmarks/README.md)
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DEFAULT_DOCS = ["docs/ARCHITECTURE.md", "README.md", "benchmarks/README.md"]

# `token` in backticks that names a file: at least one dot-extension we track.
EXTS = r"(?:py|md|ini|yml|yaml|json|txt|toml|cfg|sh)"
REF = re.compile(
    rf"`([\w./-]+\.{EXTS})"          # path.ext
    rf"(?:::([\w\[\]., -]+))?"       # optional ::symbol (pytest node)
    rf"(?::(\d+))?"                  # optional :line
    rf"`"
)


def check_doc(doc: Path) -> list[str]:
    errors = []
    text = doc.read_text()
    for m in REF.finditer(text):
        path_s, symbol, line_s = m.group(1), m.group(2), m.group(3)
        candidates = [REPO / path_s, doc.parent / path_s]
        target = next((c for c in candidates if c.is_file()), None)
        ref = m.group(0).strip("`")
        if target is None:
            errors.append(f"{doc}: `{ref}` — file not found "
                          f"(tried repo root and {doc.parent})")
            continue
        if line_s is not None:
            n_lines = len(target.read_text().splitlines())
            if int(line_s) > n_lines:
                errors.append(f"{doc}: `{ref}` — {path_s} has only "
                              f"{n_lines} lines")
        if symbol is not None:
            if symbol.split("[")[0] not in target.read_text():
                errors.append(f"{doc}: `{ref}` — symbol {symbol!r} not found "
                              f"in {path_s}")
    return errors


BUDGETS_PY = REPO / "src" / "repro" / "kernels" / "budgets.py"
ARCH_MD = REPO / "docs" / "ARCHITECTURE.md"
# First backticked token of a pinned-table row: the kernel name.
TABLE_ROW = re.compile(r"^\|\s*`([\w]+)`")


def budget_keys() -> set[str]:
    """Keys of the ``BUDGETS`` dict, by AST (no imports, no PYTHONPATH)."""
    tree = ast.parse(BUDGETS_PY.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "BUDGETS"
                for t in node.targets):
            if isinstance(node.value, ast.Dict):
                return {k.value for k in node.value.keys
                        if isinstance(k, ast.Constant)
                        and isinstance(k.value, str)}
    raise SystemExit(f"error: no literal BUDGETS dict in {BUDGETS_PY}")


def doc_table_kernels() -> set[str]:
    """Kernel names from the pinned-footprint table rows of the
    "Kernel memory plans" section of ARCHITECTURE.md."""
    out: set[str] = set()
    in_section = False
    for line in ARCH_MD.read_text().splitlines():
        if line.startswith("## "):
            in_section = line.startswith("## Kernel memory plans")
            continue
        if in_section:
            m = TABLE_ROW.match(line)
            if m and m.group(1) != "kernel":   # skip the header row
                out.add(m.group(1))
    return out


def check_budget_manifest() -> list[str]:
    if not BUDGETS_PY.is_file():
        return [f"{BUDGETS_PY}: budget manifest is missing"]
    manifest = budget_keys()
    doc = doc_table_kernels()
    errors = []
    for k in sorted(manifest - doc):
        errors.append(
            f"{ARCH_MD}: kernel `{k}` is budgeted in kernels/budgets.py but "
            "missing from the 'Kernel memory plans' pinned-footprint table")
    for k in sorted(doc - manifest):
        errors.append(
            f"{ARCH_MD}: kernel `{k}` in the 'Kernel memory plans' table has "
            "no BUDGETS entry in kernels/budgets.py")
    return errors


RULES_DIR = REPO / "src" / "repro" / "analysis" / "lint" / "rules"
# A "Static contracts" table row: `| PL001 | `shard-map-containment` | ...`
RULE_ROW = re.compile(r"^\|\s*(PL\d{3})\s*\|\s*`([\w-]+)`")


def registered_rules() -> dict[str, str]:
    """``{id: name}`` of every ``@register``-decorated rule class under the
    rules package, by AST (no imports, no PYTHONPATH)."""
    out: dict[str, str] = {}
    for path in sorted(RULES_DIR.glob("pl*.py")):
        tree = ast.parse(path.read_text())
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not any(isinstance(d, ast.Name) and d.id == "register"
                       for d in node.decorator_list):
                continue
            attrs = {}
            for stmt in node.body:
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name) \
                        and isinstance(stmt.value, ast.Constant):
                    attrs[stmt.targets[0].id] = stmt.value.value
            if "id" in attrs and "name" in attrs:
                out[attrs["id"]] = attrs["name"]
    return out


def doc_rule_table() -> dict[str, str]:
    """``{id: name}`` rows of the "Static contracts" rule table."""
    out: dict[str, str] = {}
    in_section = False
    for line in ARCH_MD.read_text().splitlines():
        if line.startswith("## "):
            in_section = line.startswith("## Static contracts")
            continue
        if in_section:
            m = RULE_ROW.match(line)
            if m:
                out[m.group(1)] = m.group(2)
    return out


def check_rule_table() -> list[str]:
    if not RULES_DIR.is_dir():
        return [f"{RULES_DIR}: planelint rules package is missing"]
    live = registered_rules()
    doc = doc_rule_table()
    errors = []
    for rid in sorted(set(live) - set(doc)):
        errors.append(
            f"{ARCH_MD}: planelint rule {rid} [{live[rid]}] is registered "
            "but missing from the 'Static contracts' rule table")
    for rid in sorted(set(doc) - set(live)):
        errors.append(
            f"{ARCH_MD}: 'Static contracts' table row {rid} [{doc[rid]}] "
            "has no registered rule in src/repro/analysis/lint/rules/")
    for rid in sorted(set(live) & set(doc)):
        if live[rid] != doc[rid]:
            errors.append(
                f"{ARCH_MD}: planelint rule {rid} is named '{live[rid]}' in "
                f"code but '{doc[rid]}' in the 'Static contracts' table")
    return errors


def main(argv: list[str]) -> int:
    docs = [Path(a) for a in argv] if argv else [REPO / d for d in DEFAULT_DOCS]
    errors, checked = [], 0
    for doc in docs:
        if not doc.is_file():
            errors.append(f"{doc}: document itself is missing")
            continue
        checked += 1
        errors.extend(check_doc(doc))
    errors.extend(check_budget_manifest())
    errors.extend(check_rule_table())
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    print(f"check_doc_refs: {checked} docs checked, {len(errors)} stale "
          f"reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Docs-consistency check: every ``path[:line]`` / ``path::symbol`` code
reference in the given markdown files must resolve against the repo.

Guards ``docs/ARCHITECTURE.md`` (the normative plane <-> kernel contract) and
the READMEs against silent rot: a reference to a file that was moved, a line
that no longer exists, or a test that was renamed fails CI.

Rules, applied to every backtick-quoted token that looks like a file path:

* the path must exist — resolved against the repo root, then against the
  markdown file's own directory (so ``benchmarks/README.md`` can list its
  sibling modules by bare name);
* ``path:N`` — the file must have at least N lines;
* ``path::name`` (pytest-style) — ``name`` must occur in the file's text.

Usage:  python tools/check_doc_refs.py [file.md ...]
        (default: docs/ARCHITECTURE.md README.md benchmarks/README.md)
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DEFAULT_DOCS = ["docs/ARCHITECTURE.md", "README.md", "benchmarks/README.md"]

# `token` in backticks that names a file: at least one dot-extension we track.
EXTS = r"(?:py|md|ini|yml|yaml|json|txt|toml|cfg|sh)"
REF = re.compile(
    rf"`([\w./-]+\.{EXTS})"          # path.ext
    rf"(?:::([\w\[\]., -]+))?"       # optional ::symbol (pytest node)
    rf"(?::(\d+))?"                  # optional :line
    rf"`"
)


def check_doc(doc: Path) -> list[str]:
    errors = []
    text = doc.read_text()
    for m in REF.finditer(text):
        path_s, symbol, line_s = m.group(1), m.group(2), m.group(3)
        candidates = [REPO / path_s, doc.parent / path_s]
        target = next((c for c in candidates if c.is_file()), None)
        ref = m.group(0).strip("`")
        if target is None:
            errors.append(f"{doc}: `{ref}` — file not found "
                          f"(tried repo root and {doc.parent})")
            continue
        if line_s is not None:
            n_lines = len(target.read_text().splitlines())
            if int(line_s) > n_lines:
                errors.append(f"{doc}: `{ref}` — {path_s} has only "
                              f"{n_lines} lines")
        if symbol is not None:
            if symbol.split("[")[0] not in target.read_text():
                errors.append(f"{doc}: `{ref}` — symbol {symbol!r} not found "
                              f"in {path_s}")
    return errors


def main(argv: list[str]) -> int:
    docs = [Path(a) for a in argv] if argv else [REPO / d for d in DEFAULT_DOCS]
    errors, checked = [], 0
    for doc in docs:
        if not doc.is_file():
            errors.append(f"{doc}: document itself is missing")
            continue
        checked += 1
        errors.extend(check_doc(doc))
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    print(f"check_doc_refs: {checked} docs checked, {len(errors)} stale "
          f"reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
